"""Cluster launcher: search an execution plan and run RLHF training.

Single-host entry point (this container); on a real fleet each host runs the
same command under its own process index and ``jax.distributed.initialize()``
stitches the global device mesh — the plan/search/runtime layers are
device-count agnostic.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
        --algo ppo --steps 5 [--nodes 2 --devs-per-node 8]
    PYTHONPATH=src python -m repro.launch.train --plan-only --arch llama-7b \
        --nodes 2 --devs-per-node 8 --h100
"""

from __future__ import annotations

import argparse
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--algo", default="ppo", choices=["ppo"])
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen-len", type=int, default=8)
    ap.add_argument("--nodes", type=int, default=1)
    ap.add_argument("--devs-per-node", type=int, default=1)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-sized)")
    ap.add_argument("--h100", action="store_true",
                    help="cost-model the paper's H100 cluster")
    ap.add_argument("--plan-only", action="store_true",
                    help="search + print the plan, do not execute")
    ap.add_argument("--search-iters", type=int, default=500)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--distributed", action="store_true",
                    help="multi-host: call jax.distributed.initialize()")
    args = ap.parse_args()

    if args.distributed:
        import jax
        jax.distributed.initialize()

    import jax
    from repro import hw
    from repro.configs import ARCHS
    from repro.core.plan import Cluster
    from repro.rlhf.experiment import ExperimentConfig, RLHFExperiment
    from repro.rlhf.ppo import PPOHyperparameters

    cfg = ARCHS[args.arch]
    if args.smoke:
        cfg = cfg.reduced()
    kw = {}
    if args.h100:
        kw = dict(chip=hw.H100, intra_node_bw=450e9, inter_node_bw=50e9)
    cluster = Cluster(n_nodes=args.nodes, devs_per_node=args.devs_per_node,
                      **kw)
    exp_cfg = ExperimentConfig(
        batch=args.batch, prompt_len=args.prompt_len, gen_len=args.gen_len,
        search_iters=args.search_iters,
        ppo=PPOHyperparameters(n_minibatches=min(2, args.batch)))

    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"cluster={args.nodes}x{args.devs_per_node}")
    run = RLHFExperiment(cfg, cfg, cluster, exp_cfg)
    print(run.plan)
    if args.plan_only:
        return

    mgr = None
    if args.ckpt:
        from repro.checkpoint.manager import CheckpointManager
        mgr = CheckpointManager(args.ckpt)

    for step in range(args.steps):
        t0 = time.time()
        out = run.run_iteration(jax.random.PRNGKey(step))
        print(f"step {step}: {time.time()-t0:.1f}s "
              f"actor_loss={out['actor_stats']['loss']:+.4f} "
              f"reward={float(out['rewards'].mean()):+.3f}", flush=True)
        if mgr and (step + 1) % 5 == 0:
            mgr.save_async(step + 1, {
                "actor": run.models["actor"].params,
                "critic": run.models["critic"].params})
    if mgr:
        mgr.wait()
    print("done")


if __name__ == "__main__":
    main()
