import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede any jax-importing module: jax locks the device count at init.
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import pathlib  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import hw  # noqa: E402
from repro.configs import ARCHS, ASSIGNED, SHAPES, cell_supported, get_config  # noqa: E402
from repro.launch import roofline as RL  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import model as MDL  # noqa: E402
from repro.models import transformer as T  # noqa: E402
from repro.optim import adamw  # noqa: E402
from repro.parallel import ctx  # noqa: E402
from repro.parallel import sharding as SH  # noqa: E402
from repro.parallel import steps as ST  # noqa: E402

ARTIFACTS = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

"""Multi-pod dry run: lower + compile every (arch x shape) cell on the
single-pod (16,16) and two-pod (2,16,16) meshes, record memory/cost analyses
and HLO collective statistics, and derive the roofline terms (§Roofline).

Artifacts are cached as JSON per cell so repeated runs are incremental.
"""


def batch_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)


def _batch_spec(bsz: int, mesh, ax):
    total = 1
    for a in ax:
        total *= mesh.shape[a]
    if bsz % total == 0:
        return ax if len(ax) > 1 else ax[0]
    if bsz == 1:
        return None
    # shard over as many axes as divide the batch
    if bsz % mesh.shape["data"] == 0:
        return "data"
    return None


def _cache_specs_tree(cache_shapes, bspec, seq_shard: bool):
    """KV caches: (n, B, S, H, Dh) — batch over data axes (or, for batch-1
    long-context cells, the sequence axis: sequence parallelism), innermost
    dim over the TP axis when divisible."""

    def spec(x):
        parts = [None] * x.ndim
        if x.ndim >= 2 and x.shape[1] > 1:
            parts[1] = bspec
        if x.ndim == 5:
            if seq_shard and x.shape[1] == 1 and x.shape[2] % 16 == 0 \
                    and x.shape[2] >= 4096:
                parts[2] = "data"
            if x.shape[-1] % 16 == 0:
                parts[-1] = "model"
        elif x.ndim == 4:  # (n, B, K, CH) conv states
            if x.shape[-1] % 16 == 0:
                parts[-1] = "model"
        return P(*parts)

    return jax.tree.map(spec, cache_shapes)


@dataclasses.dataclass
class CellSpec:
    arch: str
    shape: str
    multi_pod: bool
    variant: str = "base"

    @property
    def key(self) -> str:
        pod = "pod2" if self.multi_pod else "pod1"
        v = "" if self.variant == "base" else f"__{self.variant}"
        return f"{self.arch}__{self.shape}__{pod}{v}"


# §Perf hillclimb variants: each changes ONE lever of the execution strategy.
#   micro<k>    grad-accumulation microbatches (live-activation memory)
#   no_fsdp     params replicated over data (kills per-step param all-gathers
#               — the decode-cell fix)
#   fsdp_model  no TP: params ZeRO-sharded over the model axis, pure DP
#               activations (kills per-layer TP all-reduces — tiny-model fix)
#   dp_all      batch sharded over BOTH axes (max DP), params replicated
VARIANTS = ("base", "micro4", "micro16", "micro32", "no_fsdp",
            "fsdp_model", "dp_all", "dp_zero1")


def _variant_setup(cell: CellSpec, mesh):
    pod = "pod" if cell.multi_pod else None
    v = cell.variant
    n_micro = {"micro4": 4, "micro16": 16, "micro32": 32}.get(v, 1)
    if v == "no_fsdp":
        rules = SH.ShardingRules(tp_axis="model", fsdp_axis=None,
                                 pod_axis=pod)
        batch_ax = batch_axes(cell.multi_pod)
    elif v == "fsdp_model":
        rules = SH.ShardingRules(tp_axis=None, fsdp_axis="model",
                                 pod_axis=pod)
        batch_ax = batch_axes(cell.multi_pod)
    elif v in ("dp_all", "dp_zero1"):
        rules = SH.ShardingRules(tp_axis=None, fsdp_axis=None, pod_axis=pod)
        batch_ax = (("pod",) if cell.multi_pod else ()) + ("data", "model")
    else:
        rules = SH.ShardingRules(pod_axis=pod)
        batch_ax = batch_axes(cell.multi_pod)
    return rules, batch_ax, n_micro


def build_and_lower(cell: CellSpec, n_micro: int = 1, extra_tag: str = ""):
    cfg = get_config(cell.arch)
    shape = SHAPES[cell.shape]
    mesh = make_production_mesh(multi_pod=cell.multi_pod)
    rules, b_axes, v_micro = _variant_setup(cell, mesh)
    n_micro = max(n_micro, v_micro)
    ns = lambda s: NamedSharding(mesh, s)

    def with_ctx(fn):
        def wrapped(*a, **k):
            with ctx.use(mesh, b_axes, rules.tp_axis):
                return fn(*a, **k)
        return wrapped

    params_shapes = jax.eval_shape(
        lambda k: MDL.init_params(k, cfg), jax.random.PRNGKey(0))
    pspecs = SH.sanitize_specs(SH.param_specs(params_shapes, rules),
                               params_shapes, mesh)
    psh = jax.tree.map(ns, pspecs)

    bspec = _batch_spec(shape.global_batch, mesh, b_axes)
    kind = shape.kind

    if kind == "train":
        opt_cfg = adamw.AdamWConfig()
        opt_shapes = jax.eval_shape(lambda p: adamw.init(opt_cfg, p),
                                    params_shapes)
        if cell.variant == "dp_zero1":
            # ZeRO-1: shard optimizer states over the data axis (params stay
            # replicated for pure-DP compute; update gathers once per step)
            z1 = SH.ShardingRules(tp_axis=None, fsdp_axis=None,
                                  pod_axis="data")
            ospecs = SH.opt_state_specs(pspecs, z1, params_shapes,
                                        pod_size=mesh.shape["data"])
        else:
            ospecs = SH.opt_state_specs(pspecs, rules, params_shapes,
                                        pod_size=mesh.shape.get("pod", 2))
        ospecs = SH.sanitize_specs(ospecs, opt_shapes, mesh)
        osh = jax.tree.map(ns, ospecs)
        in_specs = MDL.input_specs(cfg, shape.seq_len, shape.global_batch,
                                   "train")
        bsh = jax.tree.map(
            lambda x: ns(P(bspec, *([None] * (x.ndim - 1)))), in_specs)
        step = with_ctx(ST.make_train_step(cfg, opt_cfg, impl="reference",
                                           remat=True, n_micro=n_micro))
        jitted = jax.jit(step, in_shardings=(psh, osh, bsh),
                         out_shardings=(psh, osh, None),
                         donate_argnums=(0, 1))
        args = (params_shapes, opt_shapes, in_specs)
    elif kind == "prefill":
        in_specs = MDL.input_specs(cfg, shape.seq_len, shape.global_batch,
                                   "prefill")
        bsh = jax.tree.map(
            lambda x: ns(P(bspec, *([None] * (x.ndim - 1)))), in_specs)
        step = with_ctx(ST.make_prefill_step(cfg, impl="reference",
                                             extra_len=1))
        jitted = jax.jit(step, in_shardings=(psh, bsh))
        args = (params_shapes, in_specs)
    else:  # decode
        bsz = shape.global_batch
        cache_shapes = ST.cache_specs(cfg, bsz, shape.seq_len + 1)
        cspecs = _cache_specs_tree(cache_shapes, bspec,
                                   seq_shard=(cell.shape == "long_500k"))
        csh = jax.tree.map(ns, cspecs)
        tok = jax.ShapeDtypeStruct((bsz,), jnp.int32)
        tok_sh = ns(P(bspec))
        step = with_ctx(ST.make_decode_step(cfg, impl="reference"))
        jitted = jax.jit(step,
                         in_shardings=(psh, tok_sh, csh, None),
                         out_shardings=(None, csh),
                         donate_argnums=(2,))
        args = (params_shapes, tok, cache_shapes,
                jax.ShapeDtypeStruct((), jnp.int32))

    lowered = jitted.lower(*args)
    return lowered, cfg, shape, mesh


# ------------------------------------------------------- superblock probes

def probe_costs(cell: CellSpec):
    """Per-superblock fwd (and train fwd+bwd) costs under the same shardings,
    used to correct cost_analysis' count-while-once behaviour."""
    cfg = get_config(cell.arch)
    shape = SHAPES[cell.shape]
    mesh = make_production_mesh(multi_pod=cell.multi_pod)
    rules, b_axes, _ = _variant_setup(cell, mesh)
    ns = lambda s: NamedSharding(mesh, s)
    bspec = _batch_spec(shape.global_batch, mesh, b_axes)

    out = []
    for specs, n in T.groups_of(cfg):
        if n <= 1:
            out.append({"trip": n, "fwd": None, "train": None})
            continue
        block_shapes = jax.eval_shape(
            lambda k: {f"b{i}": T.block_init(k, cfg, s)
                       for i, s in enumerate(specs)}, jax.random.PRNGKey(0))
        # param specs: same rules, no stack dim (path lacks "groups" already)
        bspecs = SH.sanitize_specs(SH.param_specs(block_shapes, rules),
                                   block_shapes, mesh)
        bsh = jax.tree.map(ns, bspecs)

        if shape.kind == "decode":
            bsz = shape.global_batch
            x = jax.ShapeDtypeStruct((bsz, 1, cfg.d_model), jnp.dtype(cfg.dtype))
            cache_shapes = jax.eval_shape(
                lambda: T.group_cache_init(cfg, specs, 1, bsz,
                                           shape.seq_len + 1,
                                           jnp.dtype(cfg.dtype)))
            cache_one = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype),
                cache_shapes)
            cspec = _cache_specs_tree(
                jax.tree.map(lambda s: jax.ShapeDtypeStruct((1,) + s.shape,
                                                            s.dtype),
                             cache_one), bspec,
                seq_shard=(cell.shape == "long_500k"))
            cspec = jax.tree.map(lambda p: P(*p[1:]), cspec,
                                 is_leaf=lambda x: isinstance(x, P))
            csh = jax.tree.map(ns, cspec)

            def dec_probe(xx, gp, cache):
                with ctx.use(mesh, b_axes, rules.tp_axis):
                    for i, s in enumerate(specs):
                        xx, cache[f"b{i}"] = T.block_decode(
                            gp[f"b{i}"], cfg, s, xx, cache[f"b{i}"],
                            jnp.int32(shape.seq_len - 1), impl="reference")
                    return xx, cache

            j = jax.jit(dec_probe,
                        in_shardings=(ns(P(bspec, None, None)), bsh, csh))
            comp = j.lower(x, block_shapes, cache_one).compile()
            ca = comp.cost_analysis()
            out.append({"trip": n,
                        "fwd": {"flops": ca.get("flops", 0.0),
                                "bytes": ca.get("bytes accessed", 0.0)},
                        "train": None,
                        "hlo": comp.as_text()})
            continue

        bsz, sl = shape.global_batch, shape.seq_len
        x = jax.ShapeDtypeStruct((bsz, sl, cfg.d_model), jnp.dtype(cfg.dtype))
        xsh = ns(P(bspec, None, None))

        def fwd_probe(xx, gp):
            with ctx.use(mesh, b_axes, rules.tp_axis):
                pos = jnp.arange(sl)[None, :]
                xx = ctx.constrain(xx, ctx.BATCH, None, None)
                for i, s in enumerate(specs):
                    xx, _, _ = T.block_apply(gp[f"b{i}"], cfg, s, xx, pos,
                                             impl="reference")
                return xx

        j = jax.jit(fwd_probe, in_shardings=(xsh, bsh))
        comp = j.lower(x, block_shapes).compile()
        ca = comp.cost_analysis()
        fwd = {"flops": ca.get("flops", 0.0),
               "bytes": ca.get("bytes accessed", 0.0)}

        train = None
        if shape.kind == "train":
            def train_probe(xx, gp):
                f = jax.checkpoint(fwd_probe, prevent_cse=False)
                l, grads = jax.value_and_grad(
                    lambda g: jnp.sum(f(xx, g).astype(jnp.float32)))(gp)
                return l, grads
            j2 = jax.jit(train_probe, in_shardings=(xsh, bsh))
            comp2 = j2.lower(x, block_shapes).compile()
            ca2 = comp2.cost_analysis()
            train = {"flops": ca2.get("flops", 0.0),
                     "bytes": ca2.get("bytes accessed", 0.0)}
        out.append({"trip": n, "fwd": fwd, "train": train})
    return out


# ------------------------------------------------------------- cell runner

def run_cell(cell: CellSpec, *, n_micro: int = 1, with_probes: bool = True,
             save: bool = True) -> dict:
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    path = ARTIFACTS / f"{cell.key}.json"
    if save and path.exists():
        return json.loads(path.read_text())

    cfg = get_config(cell.arch)
    shape = SHAPES[cell.shape]
    ok, why = cell_supported(cfg, shape)
    if not ok:
        res = {"cell": dataclasses.asdict(cell), "skipped": True, "why": why}
        if save:
            path.write_text(json.dumps(res, indent=1))
        return res

    t0 = time.time()
    lowered, cfg, shape, mesh = build_and_lower(cell, n_micro=n_micro)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    hlo = compiled.as_text()
    colls = RL.parse_collectives(hlo)

    flops = float(ca.get("flops", 0.0))
    bytes_ = float(ca.get("bytes accessed", 0.0))
    probes = []
    if with_probes:
        probes = probe_costs(cell)
        for pr in probes:
            body = pr["train"] if (shape.kind == "train" and pr["train"]) \
                else pr["fwd"]
            if body and pr["trip"] > 1:
                flops += (pr["trip"] - 1) * float(body["flops"])
                bytes_ += (pr["trip"] - 1) * float(body["bytes"])
            pr.pop("hlo", None)

    n_chips = mesh.devices.size
    mf = RL.model_flops(cfg, shape.kind, shape.global_batch, shape.seq_len)
    terms = RL.RooflineTerms(flops, bytes_, colls.total_wire_bytes,
                             hw.V5E, model_flops_total=mf, n_chips=n_chips)

    res = {
        "cell": dataclasses.asdict(cell),
        "skipped": False,
        "n_chips": n_chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_per_device": (ma.argument_size_in_bytes
                                + ma.output_size_in_bytes
                                + ma.temp_size_in_bytes
                                - ma.alias_size_in_bytes),
            "hbm_per_device": hw.V5E.hbm_bytes,
        },
        "cost": {"flops_raw": float(ca.get("flops", 0.0)),
                 "bytes_raw": float(ca.get("bytes accessed", 0.0)),
                 "flops_corrected": flops, "bytes_corrected": bytes_},
        "collectives": {
            "counts": colls.counts,
            "bytes_by_kind": colls.bytes_by_kind,
            "wire_bytes_by_kind": colls.wire_bytes_by_kind,
            "total_wire_bytes": colls.total_wire_bytes,
        },
        "probes": probes,
        "model_flops": mf,
        "roofline": terms.row(),
        "terms": {"flops_per_dev": flops, "hbm_bytes_per_dev": bytes_,
                  "wire_bytes_per_dev": colls.total_wire_bytes},
    }
    if save:
        path.write_text(json.dumps(res, indent=1))
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["pod1", "pod2", "both"], default="both")
    ap.add_argument("--variant", default="base", choices=VARIANTS)
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ASSIGNED
    shapes = [args.shape] if args.shape else list(SHAPES)
    pods = {"pod1": [False], "pod2": [True], "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        for shp in shapes:
            for mp in pods:
                cell = CellSpec(arch, shp, mp, args.variant)
                if args.force:
                    (ARTIFACTS / f"{cell.key}.json").unlink(missing_ok=True)
                try:
                    t0 = time.time()
                    res = run_cell(cell, n_micro=args.micro,
                                   with_probes=not args.no_probes)
                    if res.get("skipped"):
                        print(f"SKIP {cell.key}: {res['why']}")
                        continue
                    r = res["roofline"]
                    mem = res["memory"]["peak_per_device"] / 2**30
                    print(f"OK   {cell.key}: compile={res['compile_s']:.0f}s "
                          f"mem/dev={mem:.2f}GiB dominant={r['dominant']} "
                          f"[comp={r['compute_s']*1e3:.1f}ms "
                          f"mem={r['memory_s']*1e3:.1f}ms "
                          f"coll={r['collective_s']*1e3:.1f}ms] "
                          f"roofline={r['roofline_fraction']:.2%} "
                          f"({time.time()-t0:.0f}s)")
                except Exception as e:  # noqa: BLE001
                    failures.append((cell.key, repr(e)))
                    print(f"FAIL {cell.key}: {e}")
                    traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} cells failed: "
                         + ", ".join(k for k, _ in failures))
    print("all requested cells passed")


if __name__ == "__main__":
    main()
