"""Roofline analysis of compiled dry-run artifacts.

Three terms per (arch x shape x mesh) cell, all per-device / per-chip:

  compute    = HLO_FLOPs / peak_FLOP/s
  memory     = HLO_bytes / HBM_bw
  collective = ring-model wire-bytes of every HLO collective / link_bw

XLA facts this module is built around (verified in-container, see DESIGN.md):
  * ``compiled.cost_analysis()`` is per-device and counts while (scan) bodies
    ONCE -> we re-multiply using trip counts parsed from loop conditions, with
    per-body flops/bytes measured by compiling single-superblock "probe"
    functions under the same shardings.
  * collective ops are parsed from HLO text; ops inside while bodies are
    multiplied by that loop's trip count.
"""

from __future__ import annotations

import dataclasses
import re

from repro import hw

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_DTYPE_RE = r"(f64|f32|f16|bf16|f8\w*|s64|s32|s16|s8|u64|u32|u16|u8|pred)"
_SHAPE_RE = re.compile(_DTYPE_RE + r"\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> float:
    """Sum byte sizes of every tensor literal in an HLO type string."""
    total = 0.0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * hw.dtype_bytes(dt)
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    bytes_by_kind: dict
    wire_bytes_by_kind: dict

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes_by_kind.values())


def _split_computations(hlo: str) -> dict[str, list[str]]:
    """computation name -> its body lines.  Headers may have nested-tuple
    parameter types, so the param list cannot be matched with [^)]*."""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        m = re.match(r"\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(", line)
        if m and "->" in line and line.rstrip().endswith("{"):
            cur = m.group(1)
            comps[cur] = []
        elif cur is not None:
            comps[cur].append(line)
    return comps


def _while_info(hlo: str, comps: dict[str, list[str]]):
    """List of (body_name, cond_name, trip_count_or_None)."""
    out = []
    for line in hlo.splitlines():
        if " while(" not in line and "while(" not in line.strip():
            continue
        b = re.search(r"body=%?([\w\.\-]+)", line)
        c = re.search(r"condition=%?([\w\.\-]+)", line)
        if not b or not c:
            continue
        trip = None
        cond_lines = comps.get(c.group(1), [])
        for cl in cond_lines:
            m = re.search(r"compare\(.*\)", cl)
            if m and ("LT" in cl or "direction=LT" in cl):
                k = re.search(r"constant\((\d+)\)", cl)
                if k:
                    trip = int(k.group(1))
        if trip is None:  # constant may be declared on its own line
            for cl in cond_lines:
                k = re.search(r"=\s*\w+\[\]\s*constant\((\d+)\)", cl)
                if k:
                    trip = int(k.group(1))
        out.append((b.group(1), c.group(1), trip))
    return out


def _reachable(comps: dict[str, list[str]], root: str) -> set[str]:
    """Computations transitively called from ``root`` (calls, fusions, loops)."""
    seen, stack = set(), [root]
    while stack:
        cur = stack.pop()
        if cur in seen or cur not in comps:
            continue
        seen.add(cur)
        for line in comps[cur]:
            for m in re.finditer(
                    r"(?:calls=|to_apply=|body=|condition=|branch_computations=\{)%?([\w\.\-]+)",
                    line):
                stack.append(m.group(1))
    return seen


def parse_collectives(hlo: str, default_trip: int | None = None
                      ) -> CollectiveStats:
    comps = _split_computations(hlo)
    whiles = _while_info(hlo, comps)
    # multiplier per computation: product of trip counts of enclosing loops
    mult: dict[str, float] = {name: 1.0 for name in comps}
    for body, cond, trip in whiles:
        t = trip if trip is not None else (default_trip or 1)
        for c in _reachable(comps, body):
            mult[c] = mult.get(c, 1.0) * t

    counts: dict[str, float] = {}
    bytes_by: dict[str, float] = {}
    wire_by: dict[str, float] = {}
    op_re = re.compile(
        r"=\s*(.*?)\s(" + "|".join(COLLECTIVES) + r")(-start)?\(")
    for name, lines in comps.items():
        m = mult.get(name, 1.0)
        for line in lines:
            if "-done(" in line:
                continue  # async pair: counted at the -start op
            om = op_re.search(line)
            if not om:
                continue
            kind = om.group(2)
            # payload = largest tensor in the op line: equals the FULL array
            # for AG (result) / RS (input) / AR / CP (either side)
            nbytes = max((_shape_bytes(om.group(1)),
                          _largest_tensor(line)), default=0.0)
            k = _group_size(line)
            counts[kind] = counts.get(kind, 0) + m
            bytes_by[kind] = bytes_by.get(kind, 0.0) + nbytes * m
            wire = _wire_bytes(kind, nbytes, k)
            wire_by[kind] = wire_by.get(kind, 0.0) + wire * m
    return CollectiveStats(counts, bytes_by, wire_by)


def _largest_tensor(line: str) -> float:
    best = 0.0
    for m in _SHAPE_RE.finditer(line):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        best = max(best, n * hw.dtype_bytes(dt))
    return best


def _group_size(line: str) -> int:
    g = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if g:
        return len(g.group(1).split(","))
    g2 = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if g2:
        return int(g2.group(2))
    return 1


def _wire_bytes(kind: str, nbytes: float, k: int) -> float:
    """Per-device wire bytes under ring algorithms.  ``nbytes`` is the FULL
    (unsharded) payload of the collective."""
    if k <= 1:
        return 0.0
    if kind == "all-reduce":
        return hw.all_reduce_bytes(nbytes, k)
    if kind in ("all-gather", "reduce-scatter"):
        return hw.all_gather_bytes(nbytes, k)
    if kind == "all-to-all":
        return nbytes * (k - 1) / k
    return nbytes  # collective-permute: every byte crosses a link once


# ------------------------------------------------------------------ terms

@dataclasses.dataclass
class RooflineTerms:
    flops: float            # per-device, trip-corrected
    hbm_bytes: float        # per-device, trip-corrected
    wire_bytes: float       # per-device collective wire traffic
    chip: hw.ChipSpec
    model_flops_total: float = 0.0
    n_chips: int = 1

    @property
    def compute_s(self) -> float:
        return self.flops / self.chip.peak_flops_bf16

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / self.chip.hbm_bw

    @property
    def collective_s(self) -> float:
        return self.wire_bytes / self.chip.ici_link_bw

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        hlo_total = self.flops * self.n_chips
        return self.model_flops_total / hlo_total if hlo_total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the chip's peak the step achieves if it runs at the
        dominant-term time: useful_compute_time / bound_time."""
        useful_s = (self.model_flops_total / self.n_chips
                    / self.chip.peak_flops_bf16)
        return useful_s / self.bound_s if self.bound_s else 0.0

    def row(self) -> dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "useful_flops_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops(cfg, shape_kind: str, batch: int, seq_len: int) -> float:
    """MODEL_FLOPS: 6·N_active·D for train, 2·N_active·D for fwd-only; decode
    D = batch tokens (one step)."""
    n = cfg.active_param_count()
    if shape_kind == "train":
        return 6.0 * n * batch * seq_len
    if shape_kind == "prefill":
        return 2.0 * n * batch * seq_len
    return 2.0 * n * batch  # decode: one token per sequence
