"""Mesh construction, dry-run, roofline analysis, cluster launcher."""
