"""Batched serving launcher: the generation-side runtime that backs the
actor-generation function call, exposed standalone.

Requests are grouped into shape buckets (prompt length rounded up to a
power of two) so each bucket reuses one compiled prefill+decode program —
the TPU analogue of the paper's CUDAGraph decode: no per-token dispatch,
one executable per bucket.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
        --requests 12 --new 16
"""

from __future__ import annotations

import argparse
import time


def bucket_of(length: int, buckets=(16, 32, 64, 128, 256, 512, 1024)) -> int:
    from repro.models.model import bucket_len
    return bucket_len(length, buckets)


class BatchServer:
    """Minimal bucketed batch server over the functional model API."""

    def __init__(self, cfg, params, max_new: int, pad_id: int = 0):
        import jax
        from repro.models import generate
        self.cfg, self.params, self.max_new = cfg, params, max_new
        self.pad_id = pad_id
        self._gen = jax.jit(
            lambda p, b, k: generate(p, cfg, b, num_new_tokens=max_new,
                                     rng=k),
            static_argnames=())
        self._compiled_buckets = set()

    def serve(self, prompts, rng):
        """prompts: list of 1-D int32 arrays (ragged).  Returns a list of
        generated-token arrays, preserving order."""
        import jax.numpy as jnp
        by_bucket: dict[int, list[int]] = {}
        for i, pr in enumerate(prompts):
            by_bucket.setdefault(bucket_of(len(pr)), []).append(i)
        results = [None] * len(prompts)
        for bucket, idxs in sorted(by_bucket.items()):
            toks = jnp.full((len(idxs), bucket), self.pad_id, jnp.int32)
            for row, i in enumerate(idxs):
                pr = prompts[i]
                toks = toks.at[row, bucket - len(pr):].set(pr)  # left-pad
            out = self._gen(self.params, {"tokens": toks}, rng)
            self._compiled_buckets.add((len(idxs), bucket))
            for row, i in enumerate(idxs):
                results[i] = out["tokens"][row]
        return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--new", type=int, default=16)
    args = ap.parse_args()

    import jax
    import numpy as np
    from repro.configs import ARCHS
    from repro.models import init_params

    cfg = ARCHS[args.arch]
    if args.smoke:
        cfg = cfg.reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    server = BatchServer(cfg, params, max_new=args.new)

    rng = np.random.default_rng(0)
    prompts = [np.asarray(rng.integers(1, cfg.vocab_size, rng.integers(4, 40)),
                          np.int32) for _ in range(args.requests)]
    t0 = time.time()
    out = server.serve(prompts, jax.random.PRNGKey(1))
    dt = time.time() - t0
    toks = sum(len(o) for o in out)
    print(f"served {len(prompts)} ragged requests in {dt:.1f}s "
          f"({toks} new tokens, buckets={sorted(server._compiled_buckets)})")
    print("first output:", out[0][:8].tolist())


if __name__ == "__main__":
    main()
