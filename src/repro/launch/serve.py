"""Serving launchers: the generation-side runtime that backs the
actor-generation function call, exposed standalone.

Two engines share the functional model API:

``BatchServer`` (legacy baseline) groups requests into prompt-length
buckets (rounded up to a power of two) so each bucket reuses one compiled
prefill+decode program.  Every request holds a full ``max_len`` KV buffer
for its whole life and a batch runs at the pace of its longest generation.

``ContinuousBatchServer`` is the production-shaped engine: one jitted
decode step over a fixed number of slots, a paged/block KV cache
(``models/paged_cache``) so a sequence only ever holds ``ceil(len /
block_size)`` blocks, and request admission *between* steps — a finished
sequence's slot and blocks are immediately reused by queued requests, so
short requests return as they complete instead of riding out the batch's
longest generation.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
        --requests 12 --new 16 --mode continuous
"""

from __future__ import annotations

import argparse
import collections
import dataclasses
import time


def bucket_of(length: int, buckets=(16, 32, 64, 128, 256, 512, 1024)) -> int:
    from repro.models.model import bucket_len
    return bucket_len(length, buckets)


class BatchServer:
    """Minimal bucketed batch server over the functional model API."""

    def __init__(self, cfg, params, max_new: int, pad_id: int = 0,
                 eos_id=None, temperature: float = 1.0, sampler: str = "cdf",
                 top_k: int = 0, top_p: float = 1.0, impl: str = "reference"):
        import jax
        from repro.models import generate
        self.cfg, self.params, self.max_new = cfg, params, max_new
        self.pad_id = pad_id
        self._gen = jax.jit(
            lambda p, b, k: generate(p, cfg, b, num_new_tokens=max_new,
                                     rng=k, temperature=temperature,
                                     eos_id=eos_id, sampler=sampler,
                                     top_k=top_k, top_p=top_p, impl=impl),
            static_argnames=())
        self._compiled_buckets = set()

    def serve(self, prompts, rng):
        """prompts: list of 1-D int32 arrays (ragged).  Returns a list of
        generated-token arrays, preserving order."""
        import jax.numpy as jnp
        by_bucket: dict[int, list[int]] = {}
        for i, pr in enumerate(prompts):
            by_bucket.setdefault(bucket_of(len(pr)), []).append(i)
        results = [None] * len(prompts)
        for bucket, idxs in sorted(by_bucket.items()):
            toks = jnp.full((len(idxs), bucket), self.pad_id, jnp.int32)
            for row, i in enumerate(idxs):
                pr = prompts[i]
                toks = toks.at[row, bucket - len(pr):].set(pr)  # left-pad
            out = self._gen(self.params, {"tokens": toks}, rng)
            self._compiled_buckets.add((len(idxs), bucket))
            for row, i in enumerate(idxs):
                results[i] = out["tokens"][row]
        return results


# --------------------------------------------------------------- continuous

@dataclasses.dataclass
class _Request:
    rid: int
    prompt: object  # np.ndarray int32
    max_new: int
    tokens: list = dataclasses.field(default_factory=list)
    logps: list = dataclasses.field(default_factory=list)
    blocks: list = dataclasses.field(default_factory=list)

    def reset(self):  # recompute-style preemption: restart from the prompt
        self.tokens, self.logps, self.blocks = [], [], []


class ContinuousBatchServer:
    """Continuous-batching decode engine over a paged KV cache.

    One jitted decode step runs every slot each iteration (fixed shapes —
    the same no-per-token-dispatch property as the scanned ``generate``
    loop, but across *requests*): per-row positions, a block table into the
    shared KV pool, fused sampling.  Between steps the host admits queued
    requests into freed slots (a batch=1 bucketed prefill fills freshly
    allocated blocks) and retires finished rows, freeing their blocks for
    reuse.  If the pool runs dry mid-flight the youngest active request is
    preempted (blocks freed, request requeued and recomputed later) so the
    oldest requests always make progress.

    Inactive slots point at the reserved scratch block 0 and are masked on
    the host — they ride along in the fixed-shape step at zero allocation
    cost.

    ``sync_every`` amortizes host<->device round trips: each dispatch runs
    that many decode steps as one compiled ``lax.scan`` chunk and the host
    only inspects tokens (EOS / length / admission) at chunk boundaries.
    Rows that finish mid-chunk decode a few throwaway tokens into their own
    (about-to-be-freed) blocks — bounded waste, large dispatch saving.

    Passing ``draft_params``/``draft_cfg`` opts into *speculative*
    continuous batching (models/spec.py): each dispatch cycle drafts
    ``spec_k`` tokens per active slot with the small model and verifies
    them in one prefill-shaped target dispatch; rejection sampling keeps
    every returned token (and logprob) exactly the target's.  Accepted
    prefixes keep their KV blocks, rejections truncate the row's block
    list (``BlockAllocator.truncate_to``).  The draft owns a statically
    laid-out block pool per slot — preemption/recompute only ever touches
    target blocks.  EOS and per-request ``max_new`` still apply: committed
    tokens are scanned in order and any overshoot suffix is discarded
    (dropping a suffix of exact samples does not bias the distribution).
    """

    def __init__(self, cfg, params, *, n_slots: int = 8,
                 kv_block_size: int = 16, max_kv_blocks: int = 0,
                 max_prompt: int = 128, max_new: int = 128,
                 eos_id=None, temperature: float = 1.0, sampler: str = "cdf",
                 top_k: int = 0, top_p: float = 1.0, impl: str = "reference",
                 pad_id: int = 0, sync_every: int = 4,
                 prompt_buckets=(16, 32, 64, 128, 256, 512, 1024),
                 draft_params=None, draft_cfg=None, spec_k: int = 4,
                 spec_controller=None):
        import jax
        import numpy as np
        from repro.models import paged_cache as PC

        if cfg.prefix_len and cfg.family != "encdec":
            raise ValueError("ContinuousBatchServer does not support prefix "
                             "(vlm) configs")
        if (draft_params is None) != (draft_cfg is None):
            raise ValueError("draft_params and draft_cfg go together")
        self.cfg, self.params = cfg, params
        self.n_slots, self.bs = n_slots, kv_block_size
        self.max_new, self.pad_id = max_new, pad_id
        self.eos_id, self.temperature = eos_id, temperature
        self.sampler, self.top_k, self.top_p = sampler, top_k, top_p
        self.impl = impl
        self.sync_every = max(1, sync_every)
        self.prompt_buckets = prompt_buckets
        self.max_len = bucket_of(max_prompt, prompt_buckets) + max_new
        self.draft_params, self.draft_cfg = draft_params, draft_cfg
        self.spec_k = spec_k
        self.spec_controller = spec_controller
        k_cap = 0
        if draft_cfg is not None:
            from repro.models.spec import check_spec_pair
            check_spec_pair(cfg, draft_cfg)
            if spec_k < 1:
                raise ValueError(f"spec_k must be >= 1, got {spec_k}")
            k_cap = (spec_controller.k_max if spec_controller is not None
                     else spec_k)
        self._k_cap = k_cap
        # chunked decode can overshoot a row's logical end by sync_every-1
        # positions before the host trims it (a verify cycle by spec_k) —
        # budget table + pool for it
        self.max_blocks = PC.needed_blocks(
            self.max_len + max(self.sync_every - 1, k_cap + 1), self.bs)
        if max_kv_blocks <= 0:  # worst case: every slot at full length
            max_kv_blocks = PC.RESERVED_BLOCKS + n_slots * self.max_blocks
        self.alloc = PC.BlockAllocator(max_kv_blocks, self.bs)
        self.caches = PC.paged_cache_init(
            cfg, n_slots, max_kv_blocks, self.bs, self.max_len, cfg.dtype)
        self.table = np.zeros((n_slots, self.max_blocks), np.int32)
        if draft_cfg is not None:
            from repro.models.spec import _draft_table
            self.d_table = _draft_table(n_slots, self.max_blocks)
            self.d_caches = PC.paged_cache_init(
                draft_cfg, n_slots, n_slots * self.max_blocks + 1, self.bs,
                self.max_len, draft_cfg.dtype)
            self._d_table_dev = None  # lazily jnp.asarray'd (static)
        self.seq_lens = np.zeros(n_slots, np.int32)
        self.cur_tok = np.zeros(n_slots, np.int32)
        self.slots: list = [None] * n_slots
        self.queue: collections.deque = collections.deque()
        self._rng = jax.random.PRNGKey(0)
        self._step_fns: dict = {}
        self._admit_fns: dict = {}
        self.steps = 0
        self.preemptions = 0
        self.compiles = 0
        self.completion_order: list[int] = []
        self._results: dict = {}
        self._latencies: dict = {}  # rid -> seconds from serve() entry
        self._t_serve0 = None
        self.spec_cycles = 0
        self.spec_accepted = 0
        self.spec_proposed = 0
        self.spec_k_trace: list[int] = []

    # -------------------------------------------------------- compiled fns
    def _donate(self):
        import jax
        # buffer donation is a no-op warning on CPU; keep logs clean there
        return jax.default_backend() != "cpu"

    def _step_fn(self, sampled: bool):
        """One dispatch = ``sync_every`` decode steps as a compiled scan."""
        import jax
        from repro.models import model as MDL
        fn = self._step_fns.get(sampled)
        if fn is None:
            self.compiles += 1
            k_steps = self.sync_every

            def run(p, caches, tbl, pos, tok, key):
                keys = jax.random.split(key, k_steps)

                def body(carry, kk):
                    tok, pos, caches = carry
                    ntok, lp, caches = MDL.paged_decode_and_sample_step(
                        p, self.cfg, tok, caches, tbl, pos,
                        kk if sampled else None,
                        temperature=self.temperature, sampler=self.sampler,
                        top_k=self.top_k, top_p=self.top_p, impl=self.impl)
                    return (ntok, pos + 1, caches), (ntok, lp)

                (_, _, caches), (toks, lps) = jax.lax.scan(
                    body, (tok, pos, caches), keys)
                return toks, lps, caches  # (k_steps, n_slots) each

            fn = self._step_fns[sampled] = jax.jit(
                run, donate_argnums=(1,) if self._donate() else ())
        return fn

    def _admit_fn(self, plen: int, width: int, sampled: bool,
                  draft: bool = False):
        """Fused batched prefill + first-token sample + paged-cache insert:
        one dispatch admits up to ``width`` same-bucket requests (padding
        rows carry slot index ``n_slots`` — dropped by the scatter — and
        scratch-block table rows).  One program per (prompt bucket, width,
        sampled?).  The ``draft`` variant prefills the draft model into its
        own pool (no sampling — the target's admission token is the one
        committed)."""
        import jax
        from repro.kernels import ops
        from repro.models import model as MDL
        from repro.models import paged_cache as PC
        cfg = self.draft_cfg if draft else self.cfg
        key_ = (plen, width, sampled, draft)
        fn = self._admit_fns.get(key_)
        if fn is None:
            self.compiles += 1

            def run(p, caches, batch, slots, table_rows, key):
                last_h, dense = MDL.prefill(p, cfg, batch, max_len=plen,
                                            impl=self.impl)
                logits0 = MDL.logits_of(p, cfg, last_h[:, None])[:, 0]
                tok0, lp0 = ops.sample_logits(
                    logits0, key if sampled else None,
                    temperature=self.temperature, sampler=self.sampler,
                    top_k=self.top_k, top_p=self.top_p, impl=self.impl)
                caches = PC.paged_insert(cfg, caches, dense, slots,
                                         table_rows, plen)
                return tok0, lp0, caches

            fn = self._admit_fns[key_] = jax.jit(
                run, donate_argnums=(1,) if self._donate() else ())
        return fn

    def _next_key(self):
        import jax
        self._rng, k = jax.random.split(self._rng)
        return k

    # ----------------------------------------------------------- scheduling
    def _active(self):
        return [i for i, r in enumerate(self.slots) if r is not None]

    def _complete(self, slot: int):
        import numpy as np
        req = self.slots[slot]
        self._results[req.rid] = (np.asarray(req.tokens, np.int32),
                                  np.asarray(req.logps, np.float32))
        self.completion_order.append(req.rid)
        if self._t_serve0 is not None:
            self._latencies[req.rid] = time.perf_counter() - self._t_serve0
        req.blocks = self.alloc.truncate_to(req.blocks, 0)
        self.table[slot, :] = 0
        self.seq_lens[slot] = 0
        self.cur_tok[slot] = 0
        self.slots[slot] = None

    def _preempt(self, slot: int):
        """Recompute-style preemption: free the victim's blocks (a
        truncate-to-zero — the same path a rejected speculative draft takes,
        just all the way down) and requeue it (it restarts from its prompt
        on re-admission), re-inserted in arrival order so FCFS admission is
        preserved."""
        req = self.slots[slot]
        req.blocks = self.alloc.truncate_to(req.blocks, 0)
        req.reset()
        idx = 0
        while idx < len(self.queue) and self.queue[idx].rid < req.rid:
            idx += 1
        self.queue.insert(idx, req)
        self.table[slot, :] = 0
        self.seq_lens[slot] = 0
        self.cur_tok[slot] = 0
        self.slots[slot] = None
        self.preemptions += 1

    def _try_admit(self, sampled: bool):
        """Admit queued requests into free slots, batching every queued
        request that shares the head's prompt bucket into ONE fused
        prefill+insert dispatch (FCFS within a bucket; the head's bucket is
        always served first, so no starvation)."""
        import jax.numpy as jnp
        import numpy as np
        from repro.models import paged_cache as PC
        while self.queue:
            free = [i for i, r in enumerate(self.slots) if r is None]
            if not free:
                return
            head = self.queue[0]
            pb = bucket_of(len(head.prompt), self.prompt_buckets)
            nb = PC.needed_blocks(pb, self.bs)
            # same-bucket requests, as many as slots and blocks allow
            batch_reqs, budget = [], self.alloc.free_count
            for req in self.queue:
                if len(batch_reqs) >= len(free) or budget < nb:
                    break
                if bucket_of(len(req.prompt), self.prompt_buckets) != pb:
                    continue
                batch_reqs.append(req)
                budget -= nb
            if not batch_reqs:
                return  # head can't fit yet: wait for completions
            for req in batch_reqs:
                self.queue.remove(req)
            k = len(batch_reqs)
            width = 1
            while width < k:
                width *= 2
            toks = np.full((width, pb), self.pad_id, np.int32)
            slots_arr = np.full((width,), self.n_slots, np.int32)  # dropped
            table_arr = np.zeros((width, nb), np.int32)  # scratch block 0
            for row, req in enumerate(batch_reqs):
                req.blocks = self.alloc.alloc(nb)
                toks[row, pb - len(req.prompt):] = req.prompt  # left-pad
                slots_arr[row] = free[row]
                table_arr[row] = req.blocks
            tok0, lp0, self.caches = self._admit_fn(pb, width, sampled)(
                self.params, self.caches, {"tokens": jnp.asarray(toks)},
                jnp.asarray(slots_arr), jnp.asarray(table_arr),
                self._next_key())
            tok0, lp0 = np.asarray(tok0), np.asarray(lp0)
            if self.draft_cfg is not None:
                # mirror the prompt into the draft's statically-owned rows
                d_rows = np.zeros((width, nb), np.int32)
                for row in range(len(batch_reqs)):
                    d_rows[row] = self.d_table[free[row], :nb]
                _, _, self.d_caches = self._admit_fn(
                    pb, width, False, draft=True)(
                    self.draft_params, self.d_caches,
                    {"tokens": jnp.asarray(toks)}, jnp.asarray(slots_arr),
                    jnp.asarray(d_rows), self._next_key())
            for row, req in enumerate(batch_reqs):
                slot = free[row]
                req.tokens.append(int(tok0[row]))
                req.logps.append(float(lp0[row]))
                self.table[slot, :] = 0
                self.table[slot, :nb] = req.blocks
                self.seq_lens[slot] = pb
                self.cur_tok[slot] = req.tokens[-1]
                self.slots[slot] = req
                if (len(req.tokens) >= req.max_new
                        or (self.eos_id is not None
                            and req.tokens[-1] == self.eos_id)):
                    self._complete(slot)

    def _ensure_blocks(self, span=None):
        """Grow each active row's block list to cover the whole upcoming
        dispatch — ``span`` positions past the current one (default: the
        ``sync_every`` decode chunk; a speculative verify passes its draft
        length) — preempting the youngest request when the pool runs dry.

        Rows grow oldest-first, and a row never evicts an older one — if
        only older rows remain as victims, the growing row preempts
        *itself* — so the oldest request always makes forward progress."""
        if span is None:
            span = self.sync_every - 1
        for slot in sorted(self._active(),
                           key=lambda s: self.slots[s].rid):
            req = self.slots[slot]
            if req is None:  # preempted by an earlier iteration
                continue
            need = (int(self.seq_lens[slot]) + span) // self.bs
            while need >= len(req.blocks):
                if self.alloc.free_count > 0:
                    blk = self.alloc.alloc(1)[0]
                    self.table[slot, len(req.blocks)] = blk
                    req.blocks.append(blk)
                    continue
                victims = [s for s in self._active() if s != slot]
                if not victims:
                    raise MemoryError(
                        "KV pool too small for a single request; raise "
                        "max_kv_blocks")
                victim = max(victims, key=lambda s: self.slots[s].rid)
                if self.slots[victim].rid < req.rid:
                    self._preempt(slot)  # everyone else is older: yield
                    break
                self._preempt(victim)

    def _decode_step(self, sampled: bool):
        """One dispatch: ``sync_every`` decode steps for every slot, then
        host-side retirement.  A row finishing mid-chunk has its throwaway
        tail tokens dropped here (their KV went into blocks that are freed
        immediately below)."""
        import jax.numpy as jnp
        import numpy as np
        self._ensure_blocks()
        toks, lps, self.caches = self._step_fn(sampled)(
            self.params, self.caches, jnp.asarray(self.table),
            jnp.asarray(self.seq_lens), jnp.asarray(self.cur_tok),
            self._next_key())
        toks, lps = np.asarray(toks), np.asarray(lps)  # (k, n_slots)
        self.steps += 1
        for slot in self._active():
            req = self.slots[slot]
            for j in range(self.sync_every):
                self.seq_lens[slot] += 1
                t = int(toks[j, slot])
                req.tokens.append(t)
                req.logps.append(float(lps[j, slot]))
                self.cur_tok[slot] = t
                if (len(req.tokens) >= req.max_new
                        or (self.eos_id is not None and t == self.eos_id)):
                    self._complete(slot)
                    break

    def _spec_step(self, sampled: bool):
        """One speculative cycle for every slot: k+1 fused draft steps (the
        last is the consume-only catch-up), one prefill-shaped target verify
        over the k+1 spec positions, batched rejection sampling, host-side
        commit.  Inactive slots ride along against scratch block 0 exactly
        as in ``_decode_step``; their outputs are ignored.  Committed tokens
        and logprobs are exact target samples, so EOS / max_new trimming is
        a pure suffix drop."""
        import jax.numpy as jnp
        import numpy as np
        from repro.models import spec as SPEC
        ctl = self.spec_controller
        k = ctl.k if ctl is not None else self.spec_k
        self.spec_k_trace.append(k)
        # span=k+1: verify writes positions seq_lens..seq_lens+k, and a
        # clean sweep commits k+1 tokens so the post-commit truncate_to
        # keeps blocks covering index seq_lens+k+1
        self._ensure_blocks(span=k + 1)
        if self._d_table_dev is None:
            self._d_table_dev = jnp.asarray(self.d_table)
        pos0 = self.seq_lens.astype(np.int32)
        draft = SPEC._draft_run(self.draft_cfg, sampled, self.temperature,
                                self.sampler, self.top_k, self.top_p,
                                self.impl)
        verify = SPEC._verify_run(self.cfg, sampled, self.temperature,
                                  self.top_k, self.top_p, self.impl)
        keys = (jnp.stack([self._next_key() for _ in range(k + 1)])
                if sampled else jnp.zeros((k + 1, 2), jnp.uint32))
        dtoks, dlgs, self.d_caches = draft(
            self.draft_params, self.d_caches, self._d_table_dev,
            jnp.asarray(self.cur_tok), jnp.asarray(pos0), keys)
        dtoks = np.asarray(dtoks)[:, :k]
        dlgs_dev = jnp.asarray(np.asarray(dlgs)[:, :k])
        tokens = np.concatenate([self.cur_tok[:, None], dtoks], axis=1)
        positions = pos0[:, None] + np.arange(k + 1, dtype=np.int32)[None]
        acc, ytok, ylp, dlps, self.caches = verify(
            self.params, self.caches, jnp.asarray(self.table),
            jnp.asarray(tokens), jnp.asarray(positions),
            jnp.asarray(dtoks), dlgs_dev, self._next_key())
        acc, ytok = np.asarray(acc), np.asarray(ytok)
        ylp, dlps = np.asarray(ylp), np.asarray(dlps)
        self.steps += 1
        self.spec_cycles += 1
        cyc_acc = cyc_prop = 0
        for slot in self._active():
            req = self.slots[slot]
            r = int(acc[slot])
            cyc_acc += r
            cyc_prop += k
            committed = [(int(tokens[slot, 1 + j]), float(dlps[slot, j]))
                         for j in range(r)] + [(int(ytok[slot]),
                                                float(ylp[slot]))]
            for t, lp in committed:
                self.seq_lens[slot] += 1
                req.tokens.append(t)
                req.logps.append(float(lp))
                self.cur_tok[slot] = t
                if (len(req.tokens) >= req.max_new
                        or (self.eos_id is not None and t == self.eos_id)):
                    self._complete(slot)
                    break
            else:
                # row survives: drop the blocks past the committed length
                # (prompt bucket is already folded into seq_lens)
                c = int(self.seq_lens[slot]) + 1
                req.blocks = self.alloc.truncate_to(req.blocks, c)
                self.table[slot, len(req.blocks):] = 0
        self.spec_accepted += cyc_acc
        self.spec_proposed += cyc_prop
        if ctl is not None and cyc_prop:
            ctl.update(cyc_acc / cyc_prop)

    # -------------------------------------------------------------- serving
    def serve(self, prompts, rng=None, max_new=None):
        """prompts: list of 1-D int32 arrays (ragged).  ``max_new``: int or
        per-request list (default: the server's ``max_new``).  ``rng=None``
        decodes greedily.  Returns (tokens_list, logps_list) in request
        order; requests *complete* out of order (see
        ``completion_order``)."""
        import numpy as np
        if rng is not None:
            self._rng = rng
        sampled = rng is not None
        n = len(prompts)
        if max_new is None:
            max_new = self.max_new
        per_req = list(max_new) if hasattr(max_new, "__len__") \
            else [max_new] * n
        if len(per_req) != n:
            raise ValueError(f"max_new has {len(per_req)} entries for "
                             f"{n} prompts")
        base = len(self._results)
        reqs = [_Request(rid=base + i, prompt=np.asarray(p, np.int32),
                         max_new=int(m)) for i, (p, m)
                in enumerate(zip(prompts, per_req))]
        # validate before any work: a bad request must be rejected here,
        # not raise mid-flight out of _try_admit (which would lose every
        # in-flight request and leave the queue poisoned)
        for r in reqs:
            if r.max_new < 1:
                raise ValueError(f"request {r.rid}: max_new must be >= 1")
            pb = bucket_of(len(r.prompt), self.prompt_buckets)
            if pb + r.max_new > self.max_len:
                raise ValueError(
                    f"request {r.rid}: prompt bucket {pb} + max_new "
                    f"{r.max_new} exceeds max_len {self.max_len}")
        self.queue.extend(reqs)
        # per-request latency clock; restarted (and the samples reset) per
        # serve() call so stats() reflects the most recent cohort
        self._latencies = {}
        self._t_serve0 = time.perf_counter()
        spec = self.draft_cfg is not None
        while self.queue or self._active():
            self._try_admit(sampled)
            if self._active():
                if spec:
                    self._spec_step(sampled)
                else:
                    self._decode_step(sampled)
            elif self.queue:
                raise MemoryError(
                    "queued request cannot be admitted into an empty "
                    "server; raise max_kv_blocks")
        toks = [self._results[r.rid][0] for r in reqs]
        lps = [self._results[r.rid][1] for r in reqs]
        return toks, lps

    def stats(self) -> dict:
        out = {"steps": self.steps, "preemptions": self.preemptions,
               "compiles": self.compiles, "peak_blocks": self.alloc.peak,
               "completion_order": list(self.completion_order)}
        if self._latencies:
            lats = sorted(self._latencies.values())

            def pct(q):
                return lats[min(len(lats) - 1, int(q * len(lats)))]
            out["latency_s"] = {"p50": pct(0.50), "p99": pct(0.99),
                                "n": len(lats)}
        if self.draft_cfg is not None:
            out.update(
                spec_cycles=self.spec_cycles,
                spec_accepted=self.spec_accepted,
                spec_proposed=self.spec_proposed,
                spec_accept_rate=(self.spec_accepted
                                  / max(self.spec_proposed, 1)),
                spec_k_trace=list(self.spec_k_trace))
        return out

    def kv_peak_bytes(self) -> int:
        from repro.models import paged_cache as PC
        return PC.kv_pool_bytes(self.cfg, self.alloc.peak, self.bs,
                                self.cfg.dtype)


def build_server(cfg, params, exp, *, max_prompt: int = 128,
                 max_new: int = 128, draft_params=None):
    """Construct the serve engine selected by ``ExperimentConfig.serve_mode``
    ("bucketed" | "continuous"), plumbing the sampler/kv knobs through.
    With ``exp.draft_model`` set AND ``draft_params`` given, the continuous
    engine runs speculative draft-and-verify cycles."""
    if exp.serve_mode == "bucketed":
        return BatchServer(cfg, params, max_new=max_new, eos_id=exp.eos_id,
                           sampler=exp.sampler, top_k=exp.top_k,
                           top_p=exp.top_p,
                           impl=exp.rollout_impl or exp.impl)
    if exp.serve_mode != "continuous":
        raise ValueError(f"serve_mode={exp.serve_mode!r} not in "
                         "('bucketed', 'continuous')")
    spec_kw = {}
    if draft_params is not None and getattr(exp, "draft_model", None) \
            is not None:
        from repro.models.spec import SpecController
        spec_kw = dict(
            draft_params=draft_params, draft_cfg=exp.draft_model,
            spec_k=exp.spec_k,
            spec_controller=(SpecController(init_k=exp.spec_k)
                             if exp.spec_adaptive else None))
    return ContinuousBatchServer(
        cfg, params, kv_block_size=exp.kv_block_size,
        max_kv_blocks=exp.max_kv_blocks, max_prompt=max_prompt,
        max_new=max_new, eos_id=exp.eos_id, sampler=exp.sampler,
        top_k=exp.top_k, top_p=exp.top_p,
        impl=exp.rollout_impl or exp.impl, **spec_kw)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--new", type=int, default=16)
    ap.add_argument("--mode", default="continuous",
                    choices=["bucketed", "continuous"])
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--spec", action="store_true",
                    help="speculative decoding demo (self-draft: the target "
                         "drafts for itself, accept rate ~1)")
    ap.add_argument("--spec-k", type=int, default=4)
    args = ap.parse_args()

    import jax
    import numpy as np
    from repro.configs import ARCHS
    from repro.models import init_params

    cfg = ARCHS[args.arch]
    if args.smoke:
        cfg = cfg.reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)

    rng = np.random.default_rng(0)
    prompts = [np.asarray(rng.integers(1, cfg.vocab_size, rng.integers(4, 40)),
                          np.int32) for _ in range(args.requests)]
    t0 = time.time()
    if args.mode == "bucketed":
        server = BatchServer(cfg, params, max_new=args.new)
        out = server.serve(prompts, jax.random.PRNGKey(1))
        extra = f"buckets={sorted(server._compiled_buckets)}"
    else:
        spec_kw = {}
        if args.spec:
            spec_kw = dict(draft_params=params, draft_cfg=cfg,
                           spec_k=args.spec_k)
        server = ContinuousBatchServer(
            cfg, params, n_slots=args.slots, kv_block_size=args.block_size,
            max_prompt=64, max_new=args.new, **spec_kw)
        out, _ = server.serve(prompts, jax.random.PRNGKey(1))
        st = server.stats()
        extra = (f"steps={st['steps']} peak_blocks={st['peak_blocks']} "
                 f"kv_peak={server.kv_peak_bytes()}B")
        if args.spec:
            extra += (f" accept={st['spec_accept_rate']:.2f} "
                      f"cycles={st['spec_cycles']}")
    dt = time.time() - t0
    toks = sum(len(o) for o in out)
    print(f"served {len(prompts)} ragged requests in {dt:.1f}s "
          f"({toks} new tokens, mode={args.mode}, {extra})")
    print("first output:", np.asarray(out[0][:8]).tolist())


if __name__ == "__main__":
    main()
